"""L1: Pallas GEMM kernel family — the compute hot-spot of the framework.

Every convolution in the model zoo is executed as an im2col GEMM routed
through these kernels, so the whole pipeline (pre-training, ADMM primal
steps, masked retraining, inference) shares one hot path.

Kernels:
  * ``matmul``                — tiled ``C = A @ B`` (f32 accumulate)
  * ``matmul_bias_act``       — fused ``act(A @ B + bias)``
  * ``masked_matmul_bias_act``— fused ``act((W ⊙ M) @ X + bias)``; this is
    the *mask function* hot path (paper §III-B observation (iii)): the mask
    is applied inside the kernel on the VMEM-resident LHS tile rather than
    materialised in HBM — the TPU analogue of the paper's load-redundancy
    elimination (DESIGN.md §8).

All public entry points carry a ``jax.custom_vjp`` whose backward GEMMs are
routed through the same Pallas kernel, so ``jax.grad`` of any L2 graph
(train steps, ADMM primal steps) stays on the hot path.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers the kernel to plain HLO which the Rust
runtime runs unchanged. Real-TPU block-shape reasoning lives in DESIGN.md §8.

Set ``REPRO_NO_PALLAS=1`` to fall back to pure-jnp contractions (used for
the L2 ablation and as an escape hatch when profiling the lowering itself).
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.

# Default tile shapes. Chosen MXU-style (multiples of (8, 128)) so the same
# BlockSpecs are sensible on a real TPU; see DESIGN.md §8 and §Perf for the
# block sweep that picked these (overridable for the sweep itself).
BLOCK_M = int(os.environ.get("REPRO_BLOCK_M", 64))
BLOCK_N = int(os.environ.get("REPRO_BLOCK_N", 4096))
BLOCK_K = int(os.environ.get("REPRO_BLOCK_K", 1152))


def use_pallas() -> bool:
    return os.environ.get("REPRO_NO_PALLAS", "0") != "1"


def _act_fn(name, x):
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "none":
        return x
    raise ValueError(f"unknown activation {name!r}")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(x, m0, m1):
    p0 = _round_up(x.shape[0], m0) - x.shape[0]
    p1 = _round_up(x.shape[1], m1) - x.shape[1]
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _blocks(m, k, n):
    """Pick tile shapes: cap at the defaults, align small dims to the
    hardware-friendly minimum (8 sublanes / 128 lanes) instead of padding a
    16-row LHS up to 64."""
    bm = min(BLOCK_M, _round_up(m, 8))
    bn = min(BLOCK_N, _round_up(n, 128))
    bk = min(BLOCK_K, _round_up(k, 8))
    return bm, bk, bn


def _mm_kernel(a_ref, b_ref, o_ref, *, nk, act):
    """Tiled GEMM, k-innermost grid, accumulate into the output tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    if act != "none":

        @pl.when(pl.program_id(2) == nk - 1)
        def _epilogue():
            o_ref[...] = _act_fn(act, o_ref[...])


def _mm_bias_kernel(a_ref, b_ref, bias_ref, o_ref, *, nk, act):
    """Tiled GEMM with fused bias + activation epilogue."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = _act_fn(act, o_ref[...] + bias_ref[...])


def _mm_masked_bias_kernel(a_ref, m_ref, b_ref, bias_ref, o_ref, *, nk, act):
    """Tiled GEMM with the pruning mask fused into the LHS tile load."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...] * m_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = _act_fn(act, o_ref[...] + bias_ref[...])


def _pl_gemm(a, b, bias=None, mask=None, act="none"):
    """Dispatch one padded, tiled pallas_call. Inputs are upcast to f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    bm, bk, bn = _blocks(m, k, n)
    ap = _pad2(a, bm, bk)
    bp = _pad2(b, bk, bn)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    nk = grid[2]

    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    bias_spec = pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0))

    if mask is not None:
        maskp = _pad2(mask.astype(jnp.float32), bm, bk)
        biasp = _pad2(bias.astype(jnp.float32).reshape(-1, 1), bm, 1)
        out = pl.pallas_call(
            functools.partial(_mm_masked_bias_kernel, nk=nk, act=act),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            grid=grid,
            in_specs=[a_spec, a_spec, b_spec, bias_spec],
            out_specs=o_spec,
            interpret=INTERPRET,
        )(ap, maskp, bp, biasp)
    elif bias is not None:
        biasp = _pad2(bias.astype(jnp.float32).reshape(-1, 1), bm, 1)
        out = pl.pallas_call(
            functools.partial(_mm_bias_kernel, nk=nk, act=act),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            grid=grid,
            in_specs=[a_spec, b_spec, bias_spec],
            out_specs=o_spec,
            interpret=INTERPRET,
        )(ap, bp, biasp)
    else:
        out = pl.pallas_call(
            functools.partial(_mm_kernel, nk=nk, act=act),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            interpret=INTERPRET,
        )(ap, bp)
    return out[:m, :n]


def _jnp_gemm(a, b, bias=None, mask=None, act="none"):
    a = a.astype(jnp.float32)
    if mask is not None:
        a = a * mask.astype(jnp.float32)
    y = a @ b.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32).reshape(-1, 1)
    return _act_fn(act, y)


def _gemm(a, b, bias=None, mask=None, act="none"):
    if use_pallas():
        return _pl_gemm(a, b, bias=bias, mask=mask, act=act)
    return _jnp_gemm(a, b, bias=bias, mask=mask, act=act)


# --------------------------------------------------------------------------
# Public ops with custom VJPs (backward GEMMs also run on the Pallas kernel).
# --------------------------------------------------------------------------


def matmul(a, b):
    """``a @ b`` on the Pallas hot path (f32 accumulate). Differentiable."""
    return _matmul(a, b)


@jax.custom_vjp
def _matmul(a, b):
    return _gemm(a, b)


def _matmul_fwd(a, b):
    return _gemm(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = _gemm(g, b.T)
    db = _gemm(a.T, g)
    return da.astype(a.dtype), db.astype(b.dtype)


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias_act(a, b, bias, act="relu"):
    """Fused ``act(a @ b + bias[:, None])`` — the per-layer forward."""
    return _gemm(a, b, bias=bias, act=act)


def _mba_fwd(a, b, bias, act):
    y = _gemm(a, b, bias=bias, act=act)
    return y, (a, b, y)


def _mba_bwd(act, res, g):
    a, b, y = res
    if act == "relu":
        g = g * (y > 0).astype(g.dtype)
    da = _gemm(g, b.T)
    db = _gemm(a.T, g)
    dbias = jnp.sum(g, axis=1)
    return da.astype(a.dtype), db.astype(b.dtype), dbias.astype(g.dtype)


matmul_bias_act.defvjp(_mba_fwd, _mba_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def masked_matmul_bias_act(w, mask, x, bias, act="relu"):
    """Fused ``act((w ⊙ mask) @ x + bias[:, None])``.

    The mask-function op: gradients w.r.t. ``w`` are zero at pruned
    coordinates by construction (∂/∂w = (g @ xᵀ) ⊙ mask), which implements
    the paper's retraining rule "the mask function sets corresponding
    gradients as zeros for pruned weights".
    """
    return _gemm(w, x, bias=bias, mask=mask, act=act)


def _mmba_fwd(w, mask, x, bias, act):
    y = _gemm(w, x, bias=bias, mask=mask, act=act)
    return y, (w, mask, x, y)


def _mmba_bwd(act, res, g):
    w, mask, x, y = res
    if act == "relu":
        g = g * (y > 0).astype(g.dtype)
    dw = _gemm(g, x.T) * mask
    dx = _gemm((w * mask).T, g)
    dbias = jnp.sum(g, axis=1)
    return (
        dw.astype(w.dtype),
        jnp.zeros_like(mask),
        dx.astype(x.dtype),
        dbias.astype(g.dtype),
    )


masked_matmul_bias_act.defvjp(_mmba_fwd, _mmba_bwd)
