from .matmul import (  # noqa: F401
    matmul,
    matmul_bias_act,
    masked_matmul_bias_act,
    use_pallas,
    BLOCK_M,
    BLOCK_N,
    BLOCK_K,
)
