# pytest: L2 model graphs — conv-as-GEMM correctness vs lax.conv, op-list
# interpretation, shapes, and training-step semantics (incl. the mask rule).
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import arch, model

jax.config.update("jax_platform_name", "cpu")


def init_params(spec, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(spec["params"]))
    out = []
    for k, p in zip(ks, spec["params"]):
        shape = tuple(p["shape"])
        if len(shape) > 1:
            fan_in = int(np.prod(shape[1:]))
            out.append(
                jax.random.normal(k, shape) * np.sqrt(2.0 / fan_in)
            )
        else:
            out.append(jnp.zeros(shape))
    return out


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [1, 3])
def test_conv_apply_matches_lax_conv(stride, k):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 5, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (7, 5, k, k))
    b = jax.random.normal(jax.random.PRNGKey(2), (7,))
    got = model.conv_apply(x, w, b, stride, "none")
    want = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ) + b.reshape(1, -1, 1, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_masked_conv_equals_conv_of_masked_weights():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 4, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(4), (6, 4, 3, 3))
    b = jnp.zeros((6,))
    mask = (jax.random.uniform(jax.random.PRNGKey(5), (6, 36)) > 0.5).astype(
        jnp.float32
    )
    got = model.conv_apply(x, w, b, 1, "relu", mask=mask)
    want = model.conv_apply(x, w * mask.reshape(w.shape), b, 1, "relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "a", ["lenet_micro", "vgg_mini", "resnet_mini", "resnet_deep"]
)
def test_forward_shapes(a):
    spec = arch.build(a, 10, 16)
    params = init_params(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 16, 16))
    logits = model.forward(spec, params, x)
    assert logits.shape == (3, 10)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_fwd_acts_consistent_with_fwd():
    spec = arch.build("resnet_mini", 10, 16)
    params = init_params(spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16, 16))
    logits, cin, cout = model.forward(spec, params, x, collect=True)
    assert len(cin) == len(spec["prunable"]) == len(cout)
    np.testing.assert_allclose(
        logits, model.forward(spec, params, x), rtol=1e-5
    )
    # each collected output is the conv of its collected input
    for (oi, op), ti, to in zip(model.prunable_convs(spec), cin, cout):
        y = model.conv_apply(
            ti, params[op["w"]], params[op["b"]], op["stride"], op["act"]
        )
        np.testing.assert_allclose(to, y, rtol=1e-4, atol=1e-4)


def test_train_step_decreases_loss():
    spec = arch.build("lenet_micro", 10, 16)
    params = init_params(spec)
    step = model.make_train_step(spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 3, 16, 16))
    y = jax.nn.one_hot(jnp.arange(16) % 10, 10)
    lr = jnp.float32(0.05)
    args = params + [x, y, lr]
    losses = []
    for _ in range(8):
        out = step(*args)
        losses.append(float(out[-1]))
        args = list(out[:-1]) + [x, y, lr]
    assert losses[-1] < losses[0]


def test_masked_train_step_preserves_zeros():
    spec = arch.build("lenet_micro", 10, 16)
    params = init_params(spec)
    pconvs = model.prunable_convs(spec)
    masks = []
    for _, op in pconvs:
        a, q = model.gemm_shape(op)
        m = (jax.random.uniform(jax.random.PRNGKey(a), (a, q)) > 0.5).astype(
            jnp.float32
        )
        masks.append(m)
    # zero out the masked coords first (as the pruned model would be)
    for (_, op), m in zip(pconvs, masks):
        params[op["w"]] = params[op["w"]] * m.reshape(params[op["w"]].shape)
    step = model.make_masked_train_step(spec)
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 3, 16, 16))
    y = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    out = step(*(params + masks + [x, y, jnp.float32(0.05)]))
    new_params = out[:-1]
    for (_, op), m in zip(pconvs, masks):
        w = np.asarray(new_params[op["w"]]).reshape(m.shape)
        assert np.all(w[np.asarray(m) == 0] == 0.0)


def test_layer_primal_step_reduces_objective():
    spec = arch.build("lenet_micro", 10, 16)
    params = init_params(spec)
    oi = spec["prunable"][0]
    op = spec["ops"][oi]
    step = model.make_layer_primal_step(spec, oi)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, op["C"], 16, 16))
    target = jax.random.normal(
        jax.random.PRNGKey(8), (4, op["A"], op["out_hw"], op["out_hw"])
    )
    a, q = model.gemm_shape(op)
    z = jnp.zeros((a, q))
    u = jnp.zeros((a, q))
    w, b = params[op["w"]], params[op["b"]]
    rho, lr = jnp.float32(1e-3), jnp.float32(1e-3)
    losses = []
    for _ in range(5):
        w, b, loss = step(w, b, x, target, z, u, rho, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_whole_primal_step_runs_and_updates():
    spec = arch.build("lenet_micro", 10, 16)
    params = init_params(spec)
    pconvs = model.prunable_convs(spec)
    step = model.make_whole_primal_step(spec)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 3, 16, 16))
    tlogits = jax.random.normal(jax.random.PRNGKey(10), (4, 10))
    zs = [jnp.zeros(model.gemm_shape(op)) for _, op in pconvs]
    us = [jnp.zeros(model.gemm_shape(op)) for _, op in pconvs]
    out = step(*(params + [x, tlogits] + zs + us
                 + [jnp.float32(1e-3), jnp.float32(1e-3)]))
    assert len(out) == len(params) + 1
    assert np.isfinite(float(out[-1]))
    changed = any(
        not np.allclose(np.asarray(o), np.asarray(p))
        for o, p in zip(out[:-1], params)
    )
    assert changed
