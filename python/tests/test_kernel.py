# pytest: Pallas kernels vs the pure-jnp oracle (ref.py) — the CORE
# correctness signal for L1. Hypothesis sweeps shapes/dtypes; fixed cases
# pin the tile-boundary edge cases (dims below/at/above block sizes).
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIM = st.integers(min_value=1, max_value=300)
SMALL = st.integers(min_value=1, max_value=48)
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])
ACTS = st.sampled_from(["relu", "none"])


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, dtype=DTYPES)
def test_matmul_matches_ref(m, k, n, dtype):
    k1, k2 = keys(2)
    a, b = rand(k1, (m, k), dtype), rand(k2, (k, n), dtype)
    got = kernels.matmul(a, b)
    want = ref.matmul(a, b)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=SMALL, n=DIM, act=ACTS, dtype=DTYPES)
def test_matmul_bias_act_matches_ref(m, k, n, act, dtype):
    k1, k2, k3 = keys(3, seed=1)
    a, b = rand(k1, (m, k), dtype), rand(k2, (k, n), dtype)
    bias = rand(k3, (m,), jnp.float32)
    got = kernels.matmul_bias_act(a, b, bias, act=act)
    want = ref.matmul_bias_act(a, b, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@settings(max_examples=25, deadline=None)
@given(m=SMALL, k=SMALL, n=DIM, act=ACTS)
def test_masked_matmul_matches_ref(m, k, n, act):
    k1, k2, k3, k4 = keys(4, seed=2)
    w, x = rand(k1, (m, k), jnp.float32), rand(k2, (k, n), jnp.float32)
    bias = rand(k3, (m,), jnp.float32)
    mask = (jax.random.uniform(k4, (m, k)) > 0.5).astype(jnp.float32)
    got = kernels.masked_matmul_bias_act(w, mask, x, bias, act=act)
    want = ref.masked_matmul_bias_act(w, mask, x, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 128),                      # exactly one tile
        (64, 256, 256),                   # exactly the default blocks
        (65, 257, 257),                   # one past the block boundary
        (16, 27, 8192),                   # vgg first conv GEMM shape
        (128, 1152, 512),                 # vgg last conv GEMM shape
    ],
)
def test_matmul_tile_boundaries(m, k, n):
    k1, k2 = keys(2, seed=3)
    a, b = rand(k1, (m, k), jnp.float32), rand(k2, (k, n), jnp.float32)
    np.testing.assert_allclose(
        kernels.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
    )


def test_vjp_matmul_matches_jax_grad_of_ref():
    k1, k2 = keys(2, seed=4)
    a, b = rand(k1, (17, 33), jnp.float32), rand(k2, (33, 65), jnp.float32)

    def f_ker(a, b):
        return jnp.sum(kernels.matmul(a, b) ** 2)

    def f_ref(a, b):
        return jnp.sum(ref.matmul(a, b) ** 2)

    ga, gb = jax.grad(f_ker, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga, ra, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb, rb, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["relu", "none"])
def test_vjp_bias_act_matches_jax_grad_of_ref(act):
    k1, k2, k3 = keys(3, seed=5)
    a, b = rand(k1, (9, 20), jnp.float32), rand(k2, (20, 31), jnp.float32)
    bias = rand(k3, (9,), jnp.float32)

    def f(mod):
        def g(a, b, bias):
            return jnp.sum(mod.matmul_bias_act(a, b, bias, act=act) ** 3)

        return g

    got = jax.grad(f(kernels), argnums=(0, 1, 2))(a, b, bias)
    want = jax.grad(f(ref), argnums=(0, 1, 2))(a, b, bias)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["relu", "none"])
def test_vjp_masked_grad_is_zero_on_pruned_coords(act):
    """The mask-function property (paper observation (iii)): gradients of
    pruned weights are exactly zero."""
    k1, k2, k3, k4 = keys(4, seed=6)
    w, x = rand(k1, (12, 18), jnp.float32), rand(k2, (18, 40), jnp.float32)
    bias = rand(k3, (12,), jnp.float32)
    mask = (jax.random.uniform(k4, (12, 18)) > 0.6).astype(jnp.float32)

    def loss(w):
        return jnp.sum(
            kernels.masked_matmul_bias_act(w, mask, x, bias, act=act) ** 2
        )

    dw = jax.grad(loss)(w)
    assert np.all(np.asarray(dw)[np.asarray(mask) == 0] == 0.0)

    def loss_ref(w):
        return jnp.sum(
            ref.masked_matmul_bias_act(w, mask, x, bias, act=act) ** 2
        )

    np.testing.assert_allclose(
        dw, jax.grad(loss_ref)(w) * mask, rtol=1e-4, atol=1e-4
    )


def test_jnp_fallback_matches_pallas(monkeypatch):
    monkeypatch.setenv("REPRO_NO_PALLAS", "1")
    k1, k2 = keys(2, seed=7)
    a, b = rand(k1, (13, 29), jnp.float32), rand(k2, (29, 57), jnp.float32)
    fallback = kernels.matmul(a, b)
    monkeypatch.setenv("REPRO_NO_PALLAS", "0")
    pallas = kernels.matmul(a, b)
    np.testing.assert_allclose(fallback, pallas, rtol=1e-5, atol=1e-5)
