# pytest: AOT lowering machinery — catalog completeness, HLO-text format,
# manifest consistency, and incremental rebuild keys.
import json
import os
import tempfile

import jax
import pytest

from compile import aot, arch, model


def test_graph_catalog_covers_every_prunable_layer():
    spec = arch.build("resnet_mini", 10, 16)
    cat = aot.graph_catalog(spec)
    n = len(spec["prunable"])
    for j in range(n):
        assert f"layer_primal_{j}" in cat
    for name in [
        "fwd_eval",
        "fwd_acts",
        "train_step",
        "masked_train_step",
        "whole_primal_step",
        "admm_train_primal_step",
    ]:
        assert name in cat


def test_catalog_input_shapes_lower_and_eval():
    spec = arch.build("lenet_micro", 10, 16)
    cat = aot.graph_catalog(spec)
    fn, ins = cat["fwd_eval"]
    out = jax.eval_shape(fn, *[aot.sds(s) for _, s in ins])
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves[0].shape == (aot.BATCHES["eval"], 10)


def test_hlo_text_is_parseable_format():
    spec = arch.build("lenet_micro", 10, 16)
    cat = aot.graph_catalog(spec)
    fn, ins = cat["fwd_eval"]
    text = aot.to_hlo_text(fn, [aot.sds(s) for _, s in ins])
    # HLO text modules start with the module header and declare ENTRY
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # tuple return (the rust side unpacks with to_tuple)
    assert "tuple(" in text or "(f32[" in text


def test_build_model_writes_manifest_and_is_incremental():
    with tempfile.TemporaryDirectory() as d:
        entry = aot.build_model("lenet_sv10", d, only_graph="fwd_eval")
        f = os.path.join(d, entry["artifacts"]["fwd_eval"]["file"])
        assert os.path.exists(f)
        mtime = os.path.getmtime(f)
        # second build skips (key file matches)
        aot.build_model("lenet_sv10", d, only_graph="fwd_eval")
        assert os.path.getmtime(f) == mtime
        # force rewrites
        aot.build_model(
            "lenet_sv10", d, only_graph="fwd_eval", force=True
        )
        assert os.path.getmtime(f) >= mtime


def test_manifest_on_disk_matches_specs():
    # the committed artifacts/manifest.json (built by `make artifacts`)
    # must agree with a fresh arch.build for every model
    path = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    for mid, entry in man["models"].items():
        spec = arch.build(entry["arch"], entry["classes"], entry["in_hw"])
        assert entry["ops"] == spec["ops"], mid
        assert entry["params"] == spec["params"], mid
        assert entry["prunable"] == spec["prunable"], mid


@pytest.mark.parametrize("mid", list(aot.CONFIGS))
def test_all_configs_build_specs(mid):
    a, cls, hw = aot.CONFIGS[mid]
    spec = arch.build(a, cls, hw)
    assert spec["prunable"], f"{mid} has no prunable layers"
    # every prunable layer is a 3x3 conv (pattern-prunable)
    for oi in spec["prunable"]:
        op = spec["ops"][oi]
        assert op["op"] == "conv" and op["kh"] == 3 and op["kw"] == 3


def test_gemm_shapes_consistent():
    spec = arch.build("vgg_mini", 10, 16)
    for oi, op in model.prunable_convs(spec):
        a, q = model.gemm_shape(op)
        wshape = spec["params"][op["w"]]["shape"]
        assert a == wshape[0]
        assert q == wshape[1] * wshape[2] * wshape[3]
