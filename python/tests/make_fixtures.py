"""Generate cross-language numeric fixtures for the Rust integration tests.

Parameters and inputs are filled by closed-form formulas that both sides
implement independently (sin/cos ramps), so no weight files need to cross
the boundary. The fixture records the expected logits / losses computed by
the L2 JAX graphs; rust/tests/runtime_integration.rs replays the same
artifacts through PJRT and asserts allclose.

Usage: python tests/make_fixtures.py  (writes ../artifacts/fixtures.json)
"""

import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np

from compile import arch, model
from compile.aot import BATCHES


def formula_param(shape, scale=0.1):
    n = int(np.prod(shape)) if shape else 1
    v = np.array(
        [math.sin(0.1 * i) * scale for i in range(n)], dtype=np.float32
    )
    return jnp.asarray(v.reshape(shape))


def formula_input(shape):
    n = int(np.prod(shape))
    v = np.array(
        [math.cos(0.05 * i) * 0.5 + 0.5 for i in range(n)],
        dtype=np.float32,
    )
    return jnp.asarray(v.reshape(shape))


def main():
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "..",
        "artifacts",
        "fixtures.json",
    )
    spec = arch.build("lenet_micro", 10, 16)
    params = [formula_param(p["shape"]) for p in spec["params"]]

    fix = {"model": "lenet_sv10"}

    # fwd_eval logits for the formula input
    x = formula_input([BATCHES["eval"], 3, 16, 16])
    logits = model.forward(spec, params, x)
    fix["fwd_eval_logits_row0"] = [float(v) for v in np.asarray(logits)[0]]
    fix["fwd_eval_logits_row7"] = [float(v) for v in np.asarray(logits)[7]]

    # one train step: loss + a weight checksum
    xt = formula_input([BATCHES["train"], 3, 16, 16])
    yt = jnp.eye(10)[jnp.arange(BATCHES["train"]) % 10]
    step = model.make_train_step(spec)
    out = step(*(params + [xt, yt, jnp.float32(0.05)]))
    fix["train_step_loss"] = float(out[-1])
    fix["train_step_w0_sum"] = float(jnp.sum(out[0]))

    # one layer primal step on conv 0
    oi = spec["prunable"][0]
    op = spec["ops"][oi]
    b_admm = BATCHES["admm"]
    act_in = formula_input([b_admm, op["C"], op["in_hw"], op["in_hw"]])
    target = formula_input(
        [b_admm, op["A"], op["out_hw"], op["out_hw"]]
    )
    a, q = model.gemm_shape(op)
    z = formula_param([a, q], scale=0.05)
    u = formula_param([a, q], scale=0.01)
    pstep = model.make_layer_primal_step(spec, oi)
    w2, b2, loss = pstep(
        params[op["w"]], params[op["b"]], act_in, target, z, u,
        jnp.float32(1e-2), jnp.float32(1e-3),
    )
    fix["layer_primal_loss"] = float(loss)
    fix["layer_primal_w_sum"] = float(jnp.sum(w2))

    with open(out_path, "w") as f:
        json.dump(fix, f, indent=1)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
