"""Splice the generated runs/tables/*.md into EXPERIMENTS.md placeholders.

Also (fallback) assembles partial tables directly from runs/results/*.json
row caches for any table whose driver did not finish — every cached row is
still real pipeline output.

Usage: python tests/fill_experiments.py   (run from python/, like the rest)
"""

import json
import os
import re

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
TABLES = os.path.join(ROOT, "runs", "tables")
RESULTS = os.path.join(ROOT, "runs", "results")

SLOT_FILES = {
    "TABLE1": "table1.md",
    "TABLE2": "table2.md",
    "TABLE3": "table3.md",
    "TABLE4": "table4.md",
    "TABLE5": "table5.md",
    "FIG3A": "fig3_measured.md",
    "FIG3B": "fig3_estimated.md",
}


def rows_from_cache(prefix_filter):
    out = []
    if not os.path.isdir(RESULTS):
        return out
    for f in sorted(os.listdir(RESULTS)):
        if not f.endswith(".json"):
            continue
        m = re.match(
            r"(.+)_(irregular|filter|column|pattern)_"
            r"(privacy|whole|admm|uniform|oneshot|iterative)_"
            r"([\d.]+)_(\w+)\.json",
            f,
        )
        if not m or not prefix_filter(m):
            continue
        d = json.load(open(os.path.join(RESULTS, f)))
        out.append(
            (m.group(1), m.group(2), m.group(3), float(m.group(4)), d)
        )
    return out


def assemble_partial(name, prefix_filter):
    rows = rows_from_cache(prefix_filter)
    if not rows:
        return None
    lines = [
        f"### {name} (assembled from cached rows)",
        "",
        "| Network | Scheme | Method | Comp. Rate | Base Acc | Pruned Acc | Loss |",
        "|---|---|---|---|---|---|---|",
    ]
    for model, scheme, method, rate, d in rows:
        lines.append(
            "| {} | {} | {} | {:.1f}x | {:.1%} | {:.1%} | {:+.1%} |".format(
                model, scheme, method, d["comp_rate"], d["base_acc"],
                d["prune_acc"], d["base_acc"] - d["prune_acc"],
            )
        )
    return "\n".join(lines) + "\n"


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    for slot, fname in SLOT_FILES.items():
        full = os.path.join(TABLES, fname)
        if os.path.exists(full):
            content = open(full).read()
        else:
            # fallback: partial assembly from the row cache
            flt = {
                "TABLE1": lambda m: m.group(1).endswith("sv10")
                and m.group(3) in ("privacy", "admm", "oneshot", "iterative"),
                "TABLE2": lambda m: m.group(1).endswith("sv20")
                and m.group(2) == "pattern" and m.group(3) == "privacy",
                "TABLE3": lambda m: m.group(1).startswith("res")
                and m.group(4) in (4.0, 6.0) and m.group(2) == "pattern",
                "TABLE5": lambda m: m.group(1).endswith("sv10")
                and m.group(3) in ("uniform", "privacy"),
                "TABLE4": lambda m: m.group(3) in ("privacy", "whole")
                and m.group(1) == "vgg_sv10" and m.group(2) == "irregular",
            }.get(slot)
            content = assemble_partial(slot, flt) if flt else None
            if content is None:
                content = f"*(not generated in this run — see runs/ or rerun `repro exp {slot.lower()}`)*\n"
        text = text.replace(f"<!-- {slot} -->", content.strip())
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
